"""L1 correctness: Pallas kernels vs the pure-jnp oracles (ref.py).

hypothesis sweeps shapes/seeds/hyper-parameters; every kernel must agree
with its oracle to float32 tolerance for any valid configuration.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import ref
from compile.kernels.chunked import (ho_attention_chunked,
                                     linear_attention_chunked)
from compile.kernels.ho_attention import (ho_attention_causal_pallas,
                                          ho_attention_pallas)
from compile.kernels.layernorm import layernorm_noaffine_pallas
from compile.kernels.linear_attention import (
    linear_attention_causal_pallas, linear_attention_pallas)
from compile.kernels.softmax_attention import softmax_attention_pallas

jax.config.update("jax_platform_name", "cpu")

# hypothesis sweeps: seq lengths divisible by the block size choices below
SEQS = [64, 128, 256]
BLOCKS = [32, 64, 128]
DIMS = [8, 16, 32, 64]


def qkv(seed, n, d, batch=(2,)):
    key = jax.random.PRNGKey(seed)
    ks = jax.random.split(key, 3)
    shape = batch + (n, d)
    return tuple(jax.random.normal(k, shape, jnp.float32) for k in ks)


def assert_close(a, b, atol=2e-5, rtol=2e-5):
    np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                               atol=atol, rtol=rtol)


# ---------------------------------------------------------------------------
# feature-map identity: the mathematical heart of the paper
# ---------------------------------------------------------------------------

@settings(max_examples=30, deadline=None)
@given(seed=st.integers(0, 10_000), d=st.sampled_from(DIMS),
       order=st.sampled_from([0, 1, 2]),
       alpha=st.floats(0.5, 8.0))
def test_feature_map_inner_product_equals_taylor(seed, d, order, alpha):
    """<phi(q), phi(k)> == taylor_exp(q.k / (alpha sqrt d), order)."""
    key = jax.random.PRNGKey(seed)
    q, k = jax.random.normal(key, (2, 5, d), jnp.float32)
    fq = ref.ho_feature_map(q, alpha, order)
    fk = ref.ho_feature_map(k, alpha, order)
    lhs = jnp.einsum("nf,mf->nm", fq, fk)
    x = jnp.einsum("nd,md->nm", q, k) / (alpha * jnp.sqrt(jnp.float32(d)))
    rhs = ref.taylor_exp(x, order)
    assert_close(lhs, rhs, atol=1e-4, rtol=1e-4)


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 10_000), d=st.sampled_from(DIMS))
def test_feature_dim(seed, d):
    del seed
    for order, expect in [(0, 1), (1, 1 + d), (2, 1 + d + d * d)]:
        u = jnp.ones((3, d))
        assert ref.ho_feature_map(u, 3.0, order).shape == (3, expect)
        assert ref.ho_feature_dim(d, order) == expect


# ---------------------------------------------------------------------------
# factorized == direct (the linearization is exact, not approximate)
# ---------------------------------------------------------------------------

@settings(max_examples=25, deadline=None)
@given(seed=st.integers(0, 10_000), d=st.sampled_from([8, 16, 32]),
       order=st.sampled_from([0, 1, 2]), alpha=st.floats(1.0, 6.0),
       causal=st.booleans())
def test_ho_factorized_equals_direct(seed, d, order, alpha, causal):
    q, k, v = qkv(seed, 64, d)
    a = ref.ho_attention(q, k, v, order=order, alpha=alpha, causal=causal)
    b = ref.ho_attention_direct(q, k, v, order=order, alpha=alpha,
                                causal=causal)
    assert_close(a, b, atol=2e-4, rtol=2e-3)


# ---------------------------------------------------------------------------
# pallas kernels vs oracles
# ---------------------------------------------------------------------------

@settings(max_examples=12, deadline=None)
@given(seed=st.integers(0, 10_000), n=st.sampled_from(SEQS),
       d=st.sampled_from([16, 32]), block=st.sampled_from(BLOCKS),
       order=st.sampled_from([0, 1, 2]))
def test_ho_pallas_noncausal(seed, n, d, block, order):
    q, k, v = qkv(seed, n, d)
    got = ho_attention_pallas(q, k, v, order=order, block_n=block)
    want = ref.ho_attention(q, k, v, order=order)
    assert_close(got, want, atol=5e-5, rtol=5e-4)


@settings(max_examples=12, deadline=None)
@given(seed=st.integers(0, 10_000), n=st.sampled_from(SEQS),
       d=st.sampled_from([16, 32]), block=st.sampled_from(BLOCKS),
       order=st.sampled_from([1, 2]))
def test_ho_pallas_causal(seed, n, d, block, order):
    q, k, v = qkv(seed, n, d)
    got = ho_attention_causal_pallas(q, k, v, order=order, block_n=block)
    want = ref.ho_attention(q, k, v, order=order, causal=True)
    assert_close(got, want, atol=2e-4, rtol=2e-3)


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 10_000), n=st.sampled_from(SEQS),
       block=st.sampled_from(BLOCKS), causal=st.booleans())
def test_linear_pallas(seed, n, block, causal):
    q, k, v = qkv(seed, n, 32)
    if causal:
        got = linear_attention_causal_pallas(q, k, v, block_n=block)
    else:
        got = linear_attention_pallas(q, k, v, block_n=block)
    want = ref.linear_attention(q, k, v, causal=causal)
    assert_close(got, want, atol=5e-5, rtol=5e-4)


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 10_000), n=st.sampled_from(SEQS),
       block=st.sampled_from(BLOCKS), causal=st.booleans())
def test_softmax_pallas(seed, n, block, causal):
    q, k, v = qkv(seed, n, 32)
    got = softmax_attention_pallas(q, k, v, causal=causal, block_n=block)
    want = ref.softmax_attention(q, k, v, causal=causal)
    assert_close(got, want, atol=5e-5, rtol=5e-4)


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 10_000), n=st.sampled_from([50, 64, 100]),
       d=st.sampled_from(DIMS))
def test_layernorm_pallas(seed, n, d):
    key = jax.random.PRNGKey(seed)
    x = 3.0 * jax.random.normal(key, (2, n, d), jnp.float32) + 1.0
    got = layernorm_noaffine_pallas(x, block_rows=n)
    want = ref.layernorm_noaffine(x)
    assert_close(got, want, atol=2e-5, rtol=2e-4)


# ---------------------------------------------------------------------------
# chunked scan (the L2 training implementation) vs oracle
# ---------------------------------------------------------------------------

@settings(max_examples=12, deadline=None)
@given(seed=st.integers(0, 10_000), n=st.sampled_from(SEQS),
       chunk=st.sampled_from([16, 32, 64]), order=st.sampled_from([1, 2]),
       alpha=st.floats(1.0, 6.0))
def test_ho_chunked(seed, n, chunk, order, alpha):
    q, k, v = qkv(seed, n, 16)
    got = ho_attention_chunked(q, k, v, order=order, alpha=alpha,
                               chunk=chunk)
    want = ref.ho_attention(q, k, v, order=order, alpha=alpha, causal=True)
    assert_close(got, want, atol=2e-4, rtol=2e-3)


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 10_000), n=st.sampled_from(SEQS),
       chunk=st.sampled_from([16, 32, 64]))
def test_linear_chunked(seed, n, chunk):
    q, k, v = qkv(seed, n, 16)
    got = linear_attention_chunked(q, k, v, chunk=chunk)
    want = ref.linear_attention(q, k, v, causal=True)
    assert_close(got, want, atol=5e-5, rtol=5e-4)


# ---------------------------------------------------------------------------
# decode recurrence == causal attention (the RNN view)
# ---------------------------------------------------------------------------

@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 10_000), order=st.sampled_from([1, 2]))
def test_ho_decode_matches_causal(seed, order):
    n, d = 24, 16
    q, k, v = qkv(seed, n, d, batch=())
    want = ref.ho_attention(q[None], k[None], v[None], order=order,
                            causal=True)[0]
    f = ref.ho_feature_dim(d, order)
    state = (jnp.zeros((f, d)), jnp.zeros((f,)))
    outs = []
    for t in range(n):
        o, state = ref.ho_decode_step(q[t], k[t], v[t], state, order=order)
        outs.append(o)
    assert_close(jnp.stack(outs), want, atol=2e-4, rtol=2e-3)


# ---------------------------------------------------------------------------
# analytic invariants
# ---------------------------------------------------------------------------

def test_taylor_order2_denominator_positive():
    """1 + x + x^2/2 >= 1/2: the order-2 normalizer can't vanish."""
    x = jnp.linspace(-50, 50, 10_001)
    assert float(jnp.min(ref.taylor_exp(x, 2))) >= 0.5 - 1e-6


def test_constant_value_reproduced():
    """Row-normalized attention must reproduce a constant v exactly."""
    q, k, _ = qkv(0, 64, 16)
    v = jnp.full((2, 64, 16), 2.5)
    for fn in [
        lambda: ref.ho_attention(q, k, v, causal=True),
        lambda: ref.linear_attention(q, k, v, causal=True),
        lambda: ref.softmax_attention(q, k, v, causal=True),
    ]:
        assert_close(fn(), v, atol=1e-4, rtol=1e-4)


def test_order2_beats_order1_near_zero():
    """Approximation error of the attention matrix shrinks with order."""
    q, k, v = qkv(3, 128, 32)
    qn, kn = ref.layernorm_noaffine(q), ref.layernorm_noaffine(k)
    alpha = 3.0
    target = ref.softmax_attention(qn, kn, v,
                                   scale=1.0 / (alpha * np.sqrt(32)))
    errs = []
    for order in [0, 1, 2]:
        out = ref.ho_attention(q, k, v, order=order, alpha=alpha)
        errs.append(float(jnp.linalg.norm(out - target)))
    assert errs[2] < errs[1] < errs[0]


def test_rejects_order3():
    q, k, v = qkv(0, 16, 8)
    with pytest.raises(NotImplementedError):
        ref.ho_attention(q, k, v, order=3)
