"""AOT contract tests: HLO text round-trips through the XLA parser, and
the manifest stays consistent with the entry points it describes."""

import json
import pathlib

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import aot, model
from compile.configs import PRESETS

jax.config.update("jax_platform_name", "cpu")

ARTIFACTS = pathlib.Path(__file__).resolve().parents[2] / "artifacts"


def test_hlo_text_roundtrip():
    """Lower a function and parse the text back through XLA's HLO parser —
    the same parser the rust side uses (`HloModuleProto::from_text_file`).
    Numerical execution of parsed artifacts is covered by the rust
    integration tests (`holt crosscheck`)."""
    from jax._src.lib import xla_client as xc

    def fn(x, y):
        return (jnp.matmul(x, y) + 2.0,)

    spec = jax.ShapeDtypeStruct((2, 2), jnp.float32)
    text = aot.to_hlo_text(jax.jit(fn).lower(spec, spec))
    assert "ENTRY" in text and "dot(" in text

    mod = xc._xla.hlo_module_from_text(text)
    proto = mod.as_serialized_hlo_module_proto()
    assert len(proto) > 0
    # round-trip again: text -> module -> text preserves the entry shape
    assert "f32[2,2]" in mod.to_string()


@pytest.mark.skipif(not (ARTIFACTS / "manifest.json").exists(),
                    reason="run `make artifacts` first")
class TestManifest:
    @classmethod
    def setup_class(cls):
        cls.manifest = json.loads((ARTIFACTS / "manifest.json").read_text())

    def test_all_files_exist(self):
        for name, a in self.manifest["artifacts"].items():
            assert (ARTIFACTS / a["file"]).exists(), name

    def test_param_specs_match_model(self):
        for mname, m in self.manifest["models"].items():
            cfg = PRESETS[m["config"]["preset"]].with_(
                attn=m["config"]["attn"], order=m["config"]["order"],
                alpha=m["config"]["alpha"])
            spec = model.param_spec(cfg)
            assert [s["name"] for s in spec] == \
                [s["name"] for s in m["param_spec"]], mname
            assert [s["shape"] for s in spec] == \
                [s["shape"] for s in m["param_spec"]], mname
            assert m["n_params"] == cfg.n_params(), mname

    def test_train_artifact_io_arity(self):
        """train: inputs = 3*np + 5, outputs = 1 + 3*np + 1."""
        for mname, m in self.manifest["models"].items():
            train = m["artifacts"].get("train")
            if not train:
                continue
            a = self.manifest["artifacts"][train]
            np_ = len(m["param_spec"])
            assert len(a["inputs"]) == 3 * np_ + 5, mname
            assert len(a["outputs"]) == 3 * np_ + 2, mname

    def test_decode_artifact_io_arity(self):
        for mname, m in self.manifest["models"].items():
            dec = m["artifacts"].get("decode")
            if not dec:
                continue
            a = self.manifest["artifacts"][dec]
            np_, ns = len(m["param_spec"]), len(m["state_spec"])
            assert len(a["inputs"]) == np_ + ns + 2, mname
            assert len(a["outputs"]) == 1 + ns, mname
            # decode state leads with the slot dimension
            b = m["config"]["decode_batch"]
            for s in m["state_spec"]:
                assert s["shape"][0] == b, mname

    def test_ho2_state_is_constant_in_context(self):
        """The paper's O(1) claim, as recorded in the manifest: ho2 state
        sizes don't mention max_len; softmax caches do."""
        for mname, m in self.manifest["models"].items():
            c = m["config"]
            for s in m["state_spec"]:
                if c["attn"] == "softmax":
                    assert c["max_len"] in s["shape"], mname
                else:
                    dh = c["d_model"] // c["n_heads"]
                    f = (1 + dh + dh * dh if c["attn"] == "ho2"
                         and c["order"] == 2 else None)
                    assert c["max_len"] not in s["shape"][2:], mname
                    if f and s["name"].endswith(".S"):
                        assert s["shape"][2] == f, mname

    def test_dtypes_are_supported(self):
        for a in self.manifest["artifacts"].values():
            for io in a["inputs"] + a["outputs"]:
                assert io["dtype"] in ("f32", "i32")
