"""L2 correctness: model shapes, decode/forward equivalence, training
dynamics, pallas-impl equality, flatten/unflatten contract."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile import model
from compile.configs import PRESETS

jax.config.update("jax_platform_name", "cpu")

KEY = jax.random.PRNGKey(0)


def tiny(attn="ho2", **kw):
    return PRESETS["tiny"].with_(attn=attn, decode_batch=2, **kw)


def tokens(b, t, seed=1):
    return jax.random.randint(jax.random.PRNGKey(seed), (b, t), 0, 256)


def test_param_spec_counts_match():
    for preset in ["tiny", "small", "base"]:
        cfg = PRESETS[preset]
        spec = model.param_spec(cfg)
        total = sum(int(np.prod(s["shape"])) for s in spec)
        assert total == cfg.n_params(), preset


def test_flatten_unflatten_roundtrip():
    cfg = tiny()
    params = model.init_params(cfg, KEY)
    flat = model.flatten(cfg, params)
    assert len(flat) == len(model.param_spec(cfg))
    back = model.unflatten(cfg, flat)
    for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(back)):
        np.testing.assert_array_equal(a, b)


@pytest.mark.parametrize("attn", ["softmax", "linear", "ho2"])
def test_forward_shape_and_finite(attn):
    cfg = tiny(attn)
    params = model.init_params(cfg, KEY)
    logits = model.forward(cfg, params, tokens(2, 32))
    assert logits.shape == (2, 32, cfg.vocab_size)
    assert bool(jnp.all(jnp.isfinite(logits)))


@pytest.mark.parametrize("attn", ["softmax", "linear", "ho2"])
def test_decode_matches_forward(attn):
    """The recurrent O(1)-state decode must reproduce teacher-forced
    forward logits exactly — the paper's RNN equivalence, at model level."""
    cfg = tiny(attn)
    params = model.init_params(cfg, KEY)
    toks = tokens(2, 48)
    full = model.forward(cfg, params, toks)
    state = model.init_state(cfg)
    outs = []
    for t in range(48):
        lg, state = model.decode_step(cfg, params, state, toks[:, t],
                                      jnp.full((2,), t, jnp.int32))
        outs.append(lg)
    np.testing.assert_allclose(np.asarray(jnp.stack(outs, 1)),
                               np.asarray(full), atol=5e-4, rtol=5e-3)


def test_decode_state_is_constant_size():
    """ho2/linear decode state must not grow with max_len; softmax must."""
    short = tiny("ho2").with_(max_len=64)
    long_ = tiny("ho2").with_(max_len=128)
    size = lambda c: sum(int(np.prod(s["shape"])) for s in model.state_spec(c))
    assert size(short) == size(long_)
    s_short = size(tiny("softmax").with_(max_len=64))
    s_long = size(tiny("softmax").with_(max_len=128))
    assert s_long == 2 * s_short


def test_causality_of_forward():
    """Changing tokens after position p must not change logits at <= p."""
    cfg = tiny("ho2")
    params = model.init_params(cfg, KEY)
    toks = tokens(1, 32)
    toks2 = toks.at[:, 20:].set((toks[:, 20:] + 7) % 256)
    a = model.forward(cfg, params, toks)
    b = model.forward(cfg, params, toks2)
    np.testing.assert_allclose(np.asarray(a[:, :20]), np.asarray(b[:, :20]),
                               atol=1e-5, rtol=1e-4)
    assert float(jnp.max(jnp.abs(a[:, 20:] - b[:, 20:]))) > 1e-3


@pytest.mark.parametrize("attn", ["softmax", "linear", "ho2"])
def test_train_step_reduces_loss(attn):
    cfg = tiny(attn)
    params = model.init_params(cfg, KEY)
    m = jax.tree.map(jnp.zeros_like, params)
    v = jax.tree.map(jnp.zeros_like, params)
    toks = tokens(4, 32)
    tgt = jnp.roll(toks, -1, axis=1)
    w = jnp.ones(toks.shape, jnp.float32)
    step = jnp.int32(0)
    losses = []
    for _ in range(8):
        loss, params, m, v, step = model.train_step(
            cfg, params, m, v, step, toks, tgt, w, jnp.float32(1e-3))
        losses.append(float(loss))
    assert losses[-1] < losses[0] - 0.1, losses
    assert int(step) == 8


def test_loss_weights_mask_positions():
    cfg = tiny()
    params = model.init_params(cfg, KEY)
    toks = tokens(2, 16)
    tgt = jnp.roll(toks, -1, axis=1)
    w_full = jnp.ones(toks.shape, jnp.float32)
    w_none = jnp.zeros(toks.shape, jnp.float32)
    w_last = w_none.at[:, -1].set(1.0)
    l_full = model.loss_fn(cfg, params, toks, tgt, w_full)
    l_last = model.loss_fn(cfg, params, toks, tgt, w_last)
    l_none = model.loss_fn(cfg, params, toks, tgt, w_none)
    assert float(l_none) == 0.0
    assert float(l_full) > 0 and float(l_last) > 0
    assert abs(float(l_full) - float(l_last)) > 1e-6


def test_pallas_impl_matches_jnp():
    cfg = tiny("ho2")
    params = model.init_params(cfg, KEY)
    toks = tokens(2, 64)
    a = model.forward(cfg, params, toks)
    b = model.forward(cfg.with_(impl="pallas"), params, toks)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                               atol=5e-5, rtol=5e-4)


@settings(max_examples=6, deadline=None)
@given(seed=st.integers(0, 1000),
       attn=st.sampled_from(["softmax", "linear", "ho2"]))
def test_forward_deterministic(seed, attn):
    cfg = tiny(attn)
    params = model.init_params(cfg, jax.random.PRNGKey(seed))
    toks = tokens(1, 16, seed)
    a = model.forward(cfg, params, toks)
    b = model.forward(cfg, params, toks)
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_order_and_alpha_change_output():
    cfg = tiny("ho2")
    params = model.init_params(cfg, KEY)
    toks = tokens(1, 16)
    base = model.forward(cfg, params, toks)
    for variant in [cfg.with_(order=1), cfg.with_(alpha=1.0)]:
        other = model.forward(variant, params, toks)
        assert float(jnp.max(jnp.abs(base - other))) > 1e-4
