"""AOT lowering: every rust-callable entry point -> artifacts/*.hlo.txt.

This is the only place python touches the system: `make artifacts` runs it
once, after which the rust binary is self-contained.  Each entry point is
lowered with jax.jit(...).lower(...) and exported as **HLO text** — not a
serialized HloModuleProto: jax >= 0.5 emits protos with 64-bit instruction
ids which the xla crate's xla_extension 0.5.1 rejects; the text parser
reassigns ids and round-trips cleanly (see /opt/xla-example/README.md).

Alongside the HLO files it writes `manifest.json`, the full contract with
the rust side: per-artifact input/output names+shapes+dtypes, per-model
parameter and decode-state leaf specs (with init kind/std so rust owns
initialization and checkpointing), and the hyperparameters each artifact
was lowered with.

Artifacts (defaults; see --help):
    fwd_{attn}_{preset}            tokens -> logits            (jnp impl)
    fwd_ho2_tiny_pallas            same, through the L1 Pallas kernels
    train_{attn}_{preset}          fused AdamW step (loss + new state)
    train_ho2_tiny_a{A}_o{O}       alpha/order ablation grid (E6)
    decode_{attn}_{preset}         one recurrent token step (O(1) state)
    attn_{kind}_n{N}               standalone causal attention (E2 sweep)
    attn_{kind}_n{N}_pallas        Pallas variants (quickstart check)
    approx_n{N}                    softmax + ho2 alpha/order grid (E1)
"""

from __future__ import annotations

import argparse
import hashlib
import json
import pathlib
import sys
import time

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model
from .configs import PRESETS, ModelConfig
from .kernels import ref
from .kernels.chunked import ho_attention_chunked, linear_attention_chunked
from .kernels.ho_attention import ho_attention_causal_pallas
from .kernels.linear_attention import linear_attention_causal_pallas
from .kernels.softmax_attention import softmax_attention_pallas

F32, I32 = jnp.float32, jnp.int32


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (the interchange format)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True)
    return comp.as_hlo_text()


def spec(shape, dtype=F32):
    return jax.ShapeDtypeStruct(tuple(shape), dtype)


def _dt(dtype) -> str:
    return {jnp.float32: "f32", jnp.int32: "i32"}[dtype]


def _io_entry(name, s):
    return {"name": name, "shape": list(s.shape),
            "dtype": "f32" if s.dtype == jnp.float32 else "i32"}


class Registry:
    """Collects entry points, lowers them, writes files + manifest."""

    def __init__(self, out_dir: pathlib.Path, force: bool):
        self.out_dir = out_dir
        self.force = force
        self.artifacts: dict[str, dict] = {}
        self.models: dict[str, dict] = {}

    def add(self, name: str, fn, in_specs: list[tuple[str, object]],
            out_names: list[str], kind: str, meta: dict | None = None):
        path = self.out_dir / f"{name}.hlo.txt"
        specs = [s for _, s in in_specs]
        t0 = time.time()
        if self.force or not path.exists():
            lowered = jax.jit(fn).lower(*specs)
            path.write_text(to_hlo_text(lowered))
            status = f"lowered in {time.time() - t0:.1f}s"
        else:
            status = "cached"
        outs = jax.eval_shape(fn, *specs)
        self.artifacts[name] = {
            "file": path.name,
            "kind": kind,
            "inputs": [_io_entry(n, s) for n, s in in_specs],
            "outputs": [_io_entry(n, s) for n, s in zip(out_names, outs)],
            "meta": meta or {},
        }
        print(f"  {name}: {len(specs)} in / {len(outs)} out  [{status}]",
              flush=True)


# ---------------------------------------------------------------------------
# model entry points (flat-leaf calling convention, see model.param_spec)
# ---------------------------------------------------------------------------

def model_entries(reg: Registry, cfg: ModelConfig, *, with_train=True,
                  with_decode=True, with_fwd=True, suffix=""):
    np_ = len(model.param_spec(cfg))
    ns = len(model.state_spec(cfg))
    pspecs = [(s["name"], spec(s["shape"])) for s in model.param_spec(cfg)]
    sspecs = [(s["name"], spec(s["shape"])) for s in model.state_spec(cfg)]
    b, t, bd = cfg.train_batch, cfg.train_len, cfg.decode_batch
    tag = f"{cfg.attn}_{cfg.name}{suffix}"
    meta = {"preset": cfg.name, "attn": cfg.attn, "order": cfg.order,
            "alpha": cfg.alpha, "impl": cfg.impl, "model": f"{tag}"}

    if with_fwd:
        def fwd(*args):
            params = model.unflatten(cfg, list(args[:np_]))
            return (model.forward(cfg, params, args[np_]),)
        reg.add(f"fwd_{tag}", fwd,
                pspecs + [("tokens", spec((b, t), I32))], ["logits"],
                "fwd", meta)

    if with_train:
        def train(*args):
            params = model.unflatten(cfg, list(args[:np_]))
            m = model.unflatten(cfg, list(args[np_:2 * np_]))
            v = model.unflatten(cfg, list(args[2 * np_:3 * np_]))
            step, tokens, targets, weights, lr = args[3 * np_:]
            loss, p2, m2, v2, s2 = model.train_step(
                cfg, params, m, v, step, tokens, targets, weights, lr)
            return (loss, *model.flatten(cfg, p2), *model.flatten(cfg, m2),
                    *model.flatten(cfg, v2), s2)
        in_specs = (pspecs
                    + [("m." + n, s) for n, s in pspecs]
                    + [("v." + n, s) for n, s in pspecs]
                    + [("step", spec((), I32)),
                       ("tokens", spec((b, t), I32)),
                       ("targets", spec((b, t), I32)),
                       ("weights", spec((b, t), F32)),
                       ("lr", spec((), F32))])
        base = [s["name"] for s in model.param_spec(cfg)]
        out_names = (["loss"] + ["p." + n for n in base]
                     + ["m." + n for n in base]
                     + ["v." + n for n in base] + ["step"])
        reg.add(f"train_{tag}", train, in_specs, out_names, "train", meta)

    if with_decode:
        def decode(*args):
            params = model.unflatten(cfg, list(args[:np_]))
            state = list(args[np_:np_ + ns])
            token, pos = args[np_ + ns], args[np_ + ns + 1]
            logits, st2 = model.decode_step(cfg, params, state, token, pos)
            return (logits, *st2)
        in_specs = (pspecs + sspecs
                    + [("token", spec((bd,), I32)), ("pos", spec((bd,), I32))])
        out_names = ["logits"] + [s["name"] for s in model.state_spec(cfg)]
        reg.add(f"decode_{tag}", decode, in_specs, out_names, "decode", meta)

    key = tag
    reg.models[key] = {
        "config": {
            "preset": cfg.name, "vocab_size": cfg.vocab_size,
            "d_model": cfg.d_model, "n_heads": cfg.n_heads,
            "n_layers": cfg.n_layers, "d_ff": cfg.d_ff,
            "max_len": cfg.max_len, "attn": cfg.attn, "order": cfg.order,
            "alpha": cfg.alpha, "impl": cfg.impl,
            "train_batch": cfg.train_batch, "train_len": cfg.train_len,
            "decode_batch": cfg.decode_batch,
        },
        "n_params": cfg.n_params(),
        "param_spec": model.param_spec(cfg),
        "state_spec": model.state_spec(cfg),
        "artifacts": {
            **({"fwd": f"fwd_{tag}"} if with_fwd else {}),
            **({"train": f"train_{tag}"} if with_train else {}),
            **({"decode": f"decode_{tag}"} if with_decode else {}),
        },
    }


# ---------------------------------------------------------------------------
# standalone attention ops (E1 approximation quality, E2 scaling sweep)
# ---------------------------------------------------------------------------

ATTN_BH, ATTN_D = 4, 64


def attn_entries(reg: Registry, ns: list[int], pallas_n: int | None):
    for n in ns:
        qkv = [("q", spec((1, ATTN_BH, n, ATTN_D))),
               ("k", spec((1, ATTN_BH, n, ATTN_D))),
               ("v", spec((1, ATTN_BH, n, ATTN_D)))]
        meta = {"n": n, "heads": ATTN_BH, "d": ATTN_D, "causal": True}

        reg.add(f"attn_softmax_n{n}",
                lambda q, k, v: (ref.softmax_attention(q, k, v, causal=True),),
                qkv, ["out"], "attn", {**meta, "kind": "softmax"})
        reg.add(f"attn_linear_n{n}",
                lambda q, k, v: (linear_attention_chunked(q, k, v),),
                qkv, ["out"], "attn", {**meta, "kind": "linear"})
        reg.add(f"attn_ho2_n{n}",
                lambda q, k, v: (ho_attention_chunked(q, k, v),),
                qkv, ["out"], "attn",
                {**meta, "kind": "ho2", "order": 2, "alpha": 3.0})

    if pallas_n:
        n = pallas_n
        qkv = [("q", spec((1, ATTN_BH, n, ATTN_D))),
               ("k", spec((1, ATTN_BH, n, ATTN_D))),
               ("v", spec((1, ATTN_BH, n, ATTN_D)))]
        meta = {"n": n, "heads": ATTN_BH, "d": ATTN_D, "causal": True,
                "impl": "pallas"}
        reg.add(f"attn_softmax_n{n}_pallas",
                lambda q, k, v: (softmax_attention_pallas(q, k, v,
                                                          causal=True),),
                qkv, ["out"], "attn", {**meta, "kind": "softmax"})
        reg.add(f"attn_linear_n{n}_pallas",
                lambda q, k, v: (linear_attention_causal_pallas(q, k, v),),
                qkv, ["out"], "attn", {**meta, "kind": "linear"})
        reg.add(f"attn_ho2_n{n}_pallas",
                lambda q, k, v: (ho_attention_causal_pallas(q, k, v),),
                qkv, ["out"], "attn",
                {**meta, "kind": "ho2", "order": 2, "alpha": 3.0})


APPROX_ALPHAS = [1.0, 2.0, 3.0, 4.0]
APPROX_ORDERS = [0, 1, 2]


def approx_entry(reg: Registry, n: int = 256):
    """E1: one artifact, outputs = exact softmax + the ho2 (alpha, order)
    grid, all on the same inputs, non-causal (paper tests 'random data')."""
    qkv = [("q", spec((1, ATTN_BH, n, ATTN_D))),
           ("k", spec((1, ATTN_BH, n, ATTN_D))),
           ("v", spec((1, ATTN_BH, n, ATTN_D)))]

    def f(q, k, v):
        # the softmax reference the paper approximates: layer-normed q/k,
        # alpha-rescaled logits (section 3) — per (alpha) so each grid point
        # is compared against *its own* target, plus the standard softmax.
        outs = [ref.softmax_attention(q, k, v)]
        for a in APPROX_ALPHAS:
            qn, kn = ref.layernorm_noaffine(q), ref.layernorm_noaffine(k)
            outs.append(ref.softmax_attention(qn, kn, v,
                                              scale=1.0 / (a * ATTN_D**0.5)))
            for o in APPROX_ORDERS:
                outs.append(ref.ho_attention(q, k, v, order=o, alpha=a))
        return tuple(outs)

    out_names = ["softmax_std"]
    for a in APPROX_ALPHAS:
        out_names.append(f"softmax_ln_a{a:g}")
        out_names += [f"ho2_a{a:g}_o{o}" for o in APPROX_ORDERS]
    reg.add(f"approx_n{n}", f, qkv, out_names, "approx",
            {"n": n, "heads": ATTN_BH, "d": ATTN_D,
             "alphas": APPROX_ALPHAS, "orders": APPROX_ORDERS})


# ---------------------------------------------------------------------------
# main
# ---------------------------------------------------------------------------

def _input_hash() -> str:
    h = hashlib.sha256()
    root = pathlib.Path(__file__).parent
    for p in sorted(root.rglob("*.py")):
        h.update(p.read_bytes())
    return h.hexdigest()


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--presets", default="tiny,small")
    ap.add_argument("--attn", default="softmax,linear,ho2")
    ap.add_argument("--scaling-ns", default="64,128,256,512,1024,2048,4096")
    ap.add_argument("--pallas-n", type=int, default=256)
    ap.add_argument("--no-ablation", action="store_true")
    ap.add_argument("--force", action="store_true",
                    help="re-lower even if the HLO file exists")
    args = ap.parse_args(argv)

    out_dir = pathlib.Path(args.out)
    out_dir.mkdir(parents=True, exist_ok=True)
    manifest_path = out_dir / "manifest.json"
    # hash covers both the sources and the requested artifact set
    ihash = hashlib.sha256(
        (_input_hash() + repr(sorted(vars(args).items()))).encode()
    ).hexdigest()
    if manifest_path.exists() and not args.force:
        old = json.loads(manifest_path.read_text())
        if old.get("input_hash") == ihash and all(
                (out_dir / a["file"]).exists()
                for a in old["artifacts"].values()):
            print("artifacts up to date (input hash match); nothing to do")
            return

    # once the top-level hash check decides work is needed, re-lower
    # everything: per-file existence is not a freshness signal (a changed
    # entry point keeps its old filename).
    reg = Registry(out_dir, force=True)
    t0 = time.time()

    print("== model entry points ==", flush=True)
    for preset in args.presets.split(","):
        for attn in args.attn.split(","):
            cfg = PRESETS[preset].with_(attn=attn)
            model_entries(reg, cfg)

    print("== pallas forward (cross-impl check) ==", flush=True)
    model_entries(reg, PRESETS["tiny"].with_(attn="ho2", impl="pallas"),
                  with_train=False, with_decode=False, suffix="_pallas")

    if not args.no_ablation:
        print("== E6 ablation grid (tiny) ==", flush=True)
        for alpha, order in [(1.0, 2), (6.0, 2), (3.0, 1), (3.0, 0),
                             (1.0, 1)]:
            cfg = PRESETS["tiny"].with_(attn="ho2", alpha=alpha, order=order)
            model_entries(reg, cfg, with_decode=False, with_fwd=False,
                          suffix=f"_a{alpha:g}_o{order}")

    print("== E2 scaling sweep ==", flush=True)
    attn_entries(reg, [int(s) for s in args.scaling_ns.split(",")],
                 args.pallas_n)

    print("== E1 approximation grid ==", flush=True)
    approx_entry(reg)

    manifest = {
        "version": 1,
        "input_hash": ihash,
        "attn_defaults": {"heads": ATTN_BH, "d": ATTN_D},
        "artifacts": reg.artifacts,
        "models": reg.models,
    }
    manifest_path.write_text(json.dumps(manifest, indent=1))
    print(f"wrote {len(reg.artifacts)} artifacts + manifest "
          f"in {time.time() - t0:.1f}s -> {out_dir}")


if __name__ == "__main__":
    main()
