"""L2: decoder-only transformer LM with pluggable attention.

Pure-functional jax: parameters are a nested dict pytree, every entry
point is a plain function suitable for `jax.jit(...).lower(...)` (AOT,
see aot.py).  The attention inside each block is selected by
`ModelConfig.attn`:

    softmax — exact baseline (Vaswani 2017)
    linear  — elu+1 first-order linear attention (Katharopoulos 2020)
    ho2     — the paper's higher-order (Taylor order 0/1/2) linear attention

and by `ModelConfig.impl`: "jnp" uses the fused oracle from kernels/ref.py,
"pallas" the L1 kernels (interpret mode).  Both are tested equal.

The LM head is tied to the embedding.  Learned absolute positions.
Pre-LN blocks.  All activations f32.
"""

from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp

from .configs import ModelConfig
from .kernels import ref
from .kernels.chunked import ho_attention_chunked, linear_attention_chunked
from .kernels.ho_attention import (ho_attention_causal_pallas)
from .kernels.linear_attention import (linear_attention_causal_pallas)
from .kernels.softmax_attention import softmax_attention_pallas

Params = dict  # nested dict pytree


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------

def param_spec(cfg: ModelConfig) -> list[dict[str, Any]]:
    """Ordered leaf spec: name, shape, init kind — the contract with rust.

    The order here defines the flat argument order of every AOT entry
    point (see `flatten`/`unflatten`); rust initializes, checkpoints and
    feeds parameters strictly in this order (manifest.json carries it).
    """
    d, v, ff = cfg.d_model, cfg.vocab_size, cfg.d_ff
    std = 0.02
    spec: list[dict[str, Any]] = [
        {"name": "embed", "shape": [v, d], "init": "normal", "std": std},
        {"name": "pos", "shape": [cfg.max_len, d], "init": "normal",
         "std": std},
    ]
    # residual-branch output projections get the GPT-2 depth-scaled init
    std_res = std / (2 * cfg.n_layers) ** 0.5
    for i in range(cfg.n_layers):
        p = f"blocks.{i}."
        spec += [
            {"name": p + "ln1_g", "shape": [d], "init": "ones"},
            {"name": p + "ln1_b", "shape": [d], "init": "zeros"},
            {"name": p + "wq", "shape": [d, d], "init": "normal", "std": std},
            {"name": p + "wk", "shape": [d, d], "init": "normal", "std": std},
            {"name": p + "wv", "shape": [d, d], "init": "normal", "std": std},
            {"name": p + "wo", "shape": [d, d], "init": "normal",
             "std": std_res},
            {"name": p + "ln2_g", "shape": [d], "init": "ones"},
            {"name": p + "ln2_b", "shape": [d], "init": "zeros"},
            {"name": p + "w1", "shape": [d, ff], "init": "normal",
             "std": std},
            {"name": p + "b1", "shape": [ff], "init": "zeros"},
            {"name": p + "w2", "shape": [ff, d], "init": "normal",
             "std": std_res},
            {"name": p + "b2", "shape": [d], "init": "zeros"},
        ]
    spec += [
        {"name": "lnf_g", "shape": [d], "init": "ones"},
        {"name": "lnf_b", "shape": [d], "init": "zeros"},
    ]
    return spec


def init_params(cfg: ModelConfig, key: jax.Array) -> Params:
    """Initialize the parameter pytree (python-side mirror of rust init)."""
    spec = param_spec(cfg)
    keys = jax.random.split(key, len(spec))
    leaves = []
    for s, k in zip(spec, keys):
        if s["init"] == "normal":
            leaves.append(s["std"] * jax.random.normal(
                k, s["shape"], jnp.float32))
        elif s["init"] == "ones":
            leaves.append(jnp.ones(s["shape"], jnp.float32))
        else:
            leaves.append(jnp.zeros(s["shape"], jnp.float32))
    return unflatten(cfg, leaves)


def flatten(cfg: ModelConfig, params: Params) -> list[jax.Array]:
    """Params pytree -> flat leaf list in param_spec order."""
    out = []
    for s in param_spec(cfg):
        node: Any = params
        for part in s["name"].split("."):
            node = node[int(part)] if part.isdigit() else node[part]
        out.append(node)
    return out


def unflatten(cfg: ModelConfig, leaves: list[jax.Array]) -> Params:
    """Flat leaf list (param_spec order) -> params pytree."""
    it = iter(leaves)
    params: Params = {"embed": next(it), "pos": next(it), "blocks": []}
    for _ in range(cfg.n_layers):
        b = {}
        for nm in ["ln1_g", "ln1_b", "wq", "wk", "wv", "wo", "ln2_g",
                   "ln2_b", "w1", "b1", "w2", "b2"]:
            b[nm] = next(it)
        params["blocks"].append(b)
    params["lnf_g"] = next(it)
    params["lnf_b"] = next(it)
    return params


# ---------------------------------------------------------------------------
# forward
# ---------------------------------------------------------------------------

def _attention(cfg: ModelConfig, q, k, v):
    """Dispatch causal attention on (B, H, T, dh) tensors."""
    if cfg.impl == "pallas":
        if cfg.attn == "softmax":
            return softmax_attention_pallas(q, k, v, causal=True)
        if cfg.attn == "linear":
            return linear_attention_causal_pallas(q, k, v)
        return ho_attention_causal_pallas(q, k, v, order=cfg.order,
                                          alpha=cfg.alpha)
    if cfg.attn == "softmax":
        return ref.softmax_attention(q, k, v, causal=True)
    if cfg.attn == "linear":
        return linear_attention_chunked(q, k, v)
    return ho_attention_chunked(q, k, v, order=cfg.order, alpha=cfg.alpha)


def _split_heads(cfg: ModelConfig, x):
    b, t, _ = x.shape
    return x.reshape(b, t, cfg.n_heads, cfg.d_head).transpose(0, 2, 1, 3)


def _merge_heads(cfg: ModelConfig, x):
    b, h, t, dh = x.shape
    return x.transpose(0, 2, 1, 3).reshape(b, t, h * dh)


def _block(cfg: ModelConfig, p: dict, x):
    h = ref.layernorm_affine(x, p["ln1_g"], p["ln1_b"])
    q = _split_heads(cfg, h @ p["wq"])
    k = _split_heads(cfg, h @ p["wk"])
    v = _split_heads(cfg, h @ p["wv"])
    a = _merge_heads(cfg, _attention(cfg, q, k, v))
    x = x + a @ p["wo"]
    h = ref.layernorm_affine(x, p["ln2_g"], p["ln2_b"])
    x = x + (jax.nn.gelu(h @ p["w1"] + p["b1"])) @ p["w2"] + p["b2"]
    return x


def forward(cfg: ModelConfig, params: Params, tokens: jax.Array):
    """tokens (B, T) int32 -> logits (B, T, V)."""
    _, t = tokens.shape
    x = params["embed"][tokens] + params["pos"][:t][None]
    for p in params["blocks"]:
        x = _block(cfg, p, x)
    x = ref.layernorm_affine(x, params["lnf_g"], params["lnf_b"])
    return x @ params["embed"].T


def loss_fn(cfg: ModelConfig, params: Params, tokens, targets, weights):
    """Weighted next-token cross-entropy.

    weights (B, T) f32 mask the positions that count (synthetic tasks only
    score the answer span); normalized by sum of weights.
    """
    logits = forward(cfg, params, tokens)
    logz = jax.scipy.special.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, targets[..., None], axis=-1)[..., 0]
    nll = (logz - gold) * weights
    return jnp.sum(nll) / jnp.maximum(jnp.sum(weights), 1.0)


# ---------------------------------------------------------------------------
# AdamW train step (fused into one graph; lowered with buffer donation)
# ---------------------------------------------------------------------------

ADAM_B1, ADAM_B2, ADAM_EPS, WEIGHT_DECAY = 0.9, 0.999, 1e-8, 0.01


def train_step(cfg: ModelConfig, params: Params, m: Params, v: Params,
               step: jax.Array, tokens, targets, weights, lr: jax.Array):
    """One fused AdamW step. Returns (loss, params', m', v', step+1).

    Weight decay applies to matrix leaves only (embeddings/LN/bias exempt),
    matching the GPT-2 convention.
    """
    loss, grads = jax.value_and_grad(
        lambda p: loss_fn(cfg, p, tokens, targets, weights))(params)
    step = step + 1
    b1t = ADAM_B1 ** step.astype(jnp.float32)
    b2t = ADAM_B2 ** step.astype(jnp.float32)

    def upd(path_leaf):
        p, g, m_, v_, decay = path_leaf
        m_n = ADAM_B1 * m_ + (1 - ADAM_B1) * g
        v_n = ADAM_B2 * v_ + (1 - ADAM_B2) * g * g
        mhat = m_n / (1 - b1t)
        vhat = v_n / (1 - b2t)
        p_n = p - lr * (mhat / (jnp.sqrt(vhat) + ADAM_EPS) + decay * p)
        return p_n, m_n, v_n

    pl_, ml_, vl_ = flatten(cfg, params), flatten(cfg, m), flatten(cfg, v)
    gl_ = flatten(cfg, grads)
    decays = [WEIGHT_DECAY if len(s["shape"]) == 2 and
              s["name"] not in ("embed", "pos") else 0.0
              for s in param_spec(cfg)]
    out = [upd(t) for t in zip(pl_, gl_, ml_, vl_, decays)]
    params_n = unflatten(cfg, [o[0] for o in out])
    m_n = unflatten(cfg, [o[1] for o in out])
    v_n = unflatten(cfg, [o[2] for o in out])
    return loss, params_n, m_n, v_n, step


# ---------------------------------------------------------------------------
# recurrent decode step — the O(1)-state RNN view (paper section 4 /
# Katharopoulos 2020), which is what the L3 serving coordinator manages
# ---------------------------------------------------------------------------

def state_spec(cfg: ModelConfig) -> list[dict[str, Any]]:
    """Ordered decode-state leaf spec (the contract with rust).

    ho2/linear: per layer S (B, H, f, dh) and z (B, H, f) — constant in
    context length (the paper's selling point).  softmax: per layer the
    growing KV cache (B, H, max_len, dh) x2.
    """
    b, h, dh = cfg.decode_batch, cfg.n_heads, cfg.d_head
    spec = []
    for i in range(cfg.n_layers):
        if cfg.attn == "softmax":
            spec.append({"name": f"layer{i}.kcache",
                         "shape": [b, h, cfg.max_len, dh]})
            spec.append({"name": f"layer{i}.vcache",
                         "shape": [b, h, cfg.max_len, dh]})
        else:
            f = (ref.ho_feature_dim(dh, cfg.order) if cfg.attn == "ho2"
                 else dh)
            spec.append({"name": f"layer{i}.S", "shape": [b, h, f, dh]})
            spec.append({"name": f"layer{i}.z", "shape": [b, h, f]})
    return spec


def init_state(cfg: ModelConfig) -> list[jax.Array]:
    return [jnp.zeros(s["shape"], jnp.float32) for s in state_spec(cfg)]


def decode_step(cfg: ModelConfig, params: Params, state: list[jax.Array],
                token: jax.Array, pos: jax.Array):
    """One autoregressive step: token (B,) int32, pos (B,) int32.

    `pos` is *per sequence* so the rust serving coordinator can
    continuously batch requests that are at different depths (vLLM-style
    slot scheduling).  Returns (logits (B, V), new_state).  Exactly
    matches column `pos` of `forward` run on the full prefix (tested in
    python/tests).
    """
    b = token.shape[0]
    x = params["embed"][token] + params["pos"][pos]  # (B, D)
    new_state = []
    for i, p in enumerate(params["blocks"]):
        h = ref.layernorm_affine(x, p["ln1_g"], p["ln1_b"])
        q = (h @ p["wq"]).reshape(b, cfg.n_heads, cfg.d_head)
        k = (h @ p["wk"]).reshape(b, cfg.n_heads, cfg.d_head)
        v = (h @ p["wv"]).reshape(b, cfg.n_heads, cfg.d_head)
        if cfg.attn == "softmax":
            kc, vc = state[2 * i], state[2 * i + 1]
            upd = jax.vmap(
                lambda c, x_t, p: jax.lax.dynamic_update_index_in_dim(
                    c, x_t, p, axis=1))
            kc = upd(kc, k, pos)  # per-sequence cache position
            vc = upd(vc, v, pos)
            # mask: entries <= pos are visible, per sequence
            d = q.shape[-1]
            scale = 1.0 / jnp.sqrt(jnp.asarray(d, q.dtype))
            xattn = jnp.einsum("bhd,bhmd->bhm", q, kc) * scale
            idx = jnp.arange(cfg.max_len)
            xattn = jnp.where(idx[None, None, :] <= pos[:, None, None],
                              xattn, -jnp.inf)
            aw = jax.nn.softmax(xattn, axis=-1)
            a = jnp.einsum("bhm,bhmv->bhv", aw, vc)
            new_state += [kc, vc]
        elif cfg.attn == "linear":
            a, (s_n, z_n) = ref.linear_decode_step(
                q, k, v, (state[2 * i], state[2 * i + 1]))
            new_state += [s_n, z_n]
        else:
            a, (s_n, z_n) = ref.ho_decode_step(
                q, k, v, (state[2 * i], state[2 * i + 1]),
                order=cfg.order, alpha=cfg.alpha)
            new_state += [s_n, z_n]
        x = x + a.reshape(b, cfg.d_model) @ p["wo"]
        h = ref.layernorm_affine(x, p["ln2_g"], p["ln2_b"])
        x = x + (jax.nn.gelu(h @ p["w1"] + p["b1"])) @ p["w2"] + p["b2"]
    x = ref.layernorm_affine(x, params["lnf_g"], params["lnf_b"])
    return x @ params["embed"].T, new_state
