"""Pallas kernel for the paper's no-affine LayerNorm (section 3).

Row-wise mean/variance normalization over the feature axis, blocked over
rows.  The paper applies this to Q and K (without the usual gain/bias) so
that q~.k~/(a sqrt d) stays near zero, where the Taylor expansion is valid.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BLOCK_ROWS = 256


def _ln_kernel(x_ref, o_ref, *, eps):
    x = x_ref[...]
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.var(x, axis=-1, keepdims=True)
    o_ref[...] = (x - mu) * jax.lax.rsqrt(var + eps)


@functools.partial(jax.jit, static_argnames=("eps", "block_rows",
                                             "interpret"))
def _layernorm_single(x, *, eps=1e-5, block_rows=DEFAULT_BLOCK_ROWS,
                      interpret=True):
    n, d = x.shape
    bn = min(block_rows, n)
    assert n % bn == 0
    return pl.pallas_call(
        functools.partial(_ln_kernel, eps=eps),
        grid=(n // bn,),
        in_specs=[pl.BlockSpec((bn, d), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((bn, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n, d), jnp.float32),
        interpret=interpret,
    )(x)


def layernorm_noaffine_pallas(x, *, eps=1e-5, block_rows=DEFAULT_BLOCK_ROWS,
                              interpret=True):
    """No-affine LayerNorm over the last axis; x: (..., n, d)."""
    fn = functools.partial(_layernorm_single, eps=eps, block_rows=block_rows,
                           interpret=interpret)
    for _ in range(x.ndim - 2):
        fn = jax.vmap(fn)
    return fn(x)
