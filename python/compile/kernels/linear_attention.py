"""Pallas kernel for the first-order baseline: elu(x)+1 linear attention.

This is the Katharopoulos et al. 2020 model the paper forks from.  Same
blocked structure as ho_attention.py — state sweep + query sweep for the
non-causal case, chunked scan for the causal case — with the feature map
``phi(u) = elu(u) + 1`` (dim d instead of 1+d+d^2).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .ref import EPS_DEN

DEFAULT_BLOCK_N = 128


def _elu1(u):
    return jnp.where(u > 0, u + 1.0, jnp.exp(u))


def _state_kernel(k_ref, v_ref, s_ref, z_ref):
    @pl.when(pl.program_id(0) == 0)
    def _init():
        s_ref[...] = jnp.zeros_like(s_ref)
        z_ref[...] = jnp.zeros_like(z_ref)
    fk = _elu1(k_ref[...])
    s_ref[...] += jax.lax.dot_general(fk, v_ref[...], (((0,), (0,)), ((), ())),
                                      preferred_element_type=jnp.float32)
    z_ref[...] += jnp.sum(fk, axis=0, keepdims=True)


def _query_kernel(q_ref, s_ref, z_ref, o_ref):
    fq = _elu1(q_ref[...])
    num = jax.lax.dot_general(fq, s_ref[...], (((1,), (0,)), ((), ())),
                              preferred_element_type=jnp.float32)
    den = jax.lax.dot_general(fq, z_ref[...], (((1,), (1,)), ((), ())),
                              preferred_element_type=jnp.float32)
    o_ref[...] = num / jnp.maximum(den, EPS_DEN)


@functools.partial(jax.jit, static_argnames=("block_n", "interpret"))
def _linear_attention_single(q, k, v, *, block_n=DEFAULT_BLOCK_N,
                             interpret=True):
    n, d = q.shape
    dv = v.shape[-1]
    bn = min(block_n, n)
    assert n % bn == 0

    s_mat, z = pl.pallas_call(
        _state_kernel,
        grid=(n // bn,),
        in_specs=[pl.BlockSpec((bn, d), lambda i: (i, 0)),
                  pl.BlockSpec((bn, dv), lambda i: (i, 0))],
        out_specs=[pl.BlockSpec((d, dv), lambda i: (0, 0)),
                   pl.BlockSpec((1, d), lambda i: (0, 0))],
        out_shape=[jax.ShapeDtypeStruct((d, dv), jnp.float32),
                   jax.ShapeDtypeStruct((1, d), jnp.float32)],
        interpret=interpret,
    )(k, v)

    return pl.pallas_call(
        _query_kernel,
        grid=(n // bn,),
        in_specs=[pl.BlockSpec((bn, d), lambda i: (i, 0)),
                  pl.BlockSpec((d, dv), lambda i: (0, 0)),
                  pl.BlockSpec((1, d), lambda i: (0, 0))],
        out_specs=pl.BlockSpec((bn, dv), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n, dv), jnp.float32),
        interpret=interpret,
    )(q, s_mat, z)


def linear_attention_pallas(q, k, v, *, block_n=DEFAULT_BLOCK_N,
                            interpret=True):
    """Non-causal elu+1 linear attention; q/k/v: (..., n, d)."""
    fn = functools.partial(_linear_attention_single, block_n=block_n,
                           interpret=interpret)
    for _ in range(q.ndim - 2):
        fn = jax.vmap(fn)
    return fn(q, k, v)


def _causal_kernel(q_ref, k_ref, v_ref, o_ref, s_ref, z_ref):
    @pl.when(pl.program_id(0) == 0)
    def _init():
        s_ref[...] = jnp.zeros_like(s_ref)
        z_ref[...] = jnp.zeros_like(z_ref)

    fq, fk = _elu1(q_ref[...]), _elu1(k_ref[...])
    v = v_ref[...]

    num = jax.lax.dot_general(fq, s_ref[...], (((1,), (0,)), ((), ())),
                              preferred_element_type=jnp.float32)
    den = jax.lax.dot_general(fq, z_ref[...], (((1,), (1,)), ((), ())),
                              preferred_element_type=jnp.float32)

    a = jax.lax.dot_general(fq, fk, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32)
    rows = jax.lax.broadcasted_iota(jnp.int32, a.shape, 0)
    cols = jax.lax.broadcasted_iota(jnp.int32, a.shape, 1)
    a = jnp.where(rows >= cols, a, 0.0)
    num += jax.lax.dot_general(a, v, (((1,), (0,)), ((), ())),
                               preferred_element_type=jnp.float32)
    den += jnp.sum(a, axis=-1, keepdims=True)

    o_ref[...] = num / jnp.maximum(den, EPS_DEN)

    s_ref[...] += jax.lax.dot_general(fk, v, (((0,), (0,)), ((), ())),
                                      preferred_element_type=jnp.float32)
    z_ref[...] += jnp.sum(fk, axis=0, keepdims=True)


@functools.partial(jax.jit, static_argnames=("block_n", "interpret"))
def _linear_attention_causal_single(q, k, v, *, block_n=DEFAULT_BLOCK_N,
                                    interpret=True):
    n, d = q.shape
    dv = v.shape[-1]
    bn = min(block_n, n)
    assert n % bn == 0

    return pl.pallas_call(
        _causal_kernel,
        grid=(n // bn,),
        in_specs=[pl.BlockSpec((bn, d), lambda i: (i, 0)),
                  pl.BlockSpec((bn, d), lambda i: (i, 0)),
                  pl.BlockSpec((bn, dv), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((bn, dv), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n, dv), jnp.float32),
        scratch_shapes=[pltpu.VMEM((d, dv), jnp.float32),
                        pltpu.VMEM((1, d), jnp.float32)],
        interpret=interpret,
    )(q, k, v)


def linear_attention_causal_pallas(q, k, v, *, block_n=DEFAULT_BLOCK_N,
                                   interpret=True):
    """Causal elu+1 linear attention; q/k/v: (..., n, d)."""
    fn = functools.partial(_linear_attention_causal_single, block_n=block_n,
                           interpret=interpret)
    for _ in range(q.ndim - 2):
        fn = jax.vmap(fn)
    return fn(q, k, v)
