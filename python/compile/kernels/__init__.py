"""L1 Pallas kernels + pure-jnp oracles for the HOLT reproduction.

Layout:
    ref.py                pure-jnp ground truth for everything below
    ho_attention.py       the paper's order-{0,1,2} linear attention
    linear_attention.py   elu+1 first-order baseline (Katharopoulos 2020)
    softmax_attention.py  exact blocked softmax baseline (flash-style)
    layernorm.py          no-affine LayerNorm (paper section 3)

All kernels run with interpret=True: the CPU PJRT plugin cannot execute
Mosaic custom-calls, so interpret mode is the supported lowering for this
testbed (see /opt/xla-example/README.md).  Block shapes are chosen for the
TPU VMEM/MXU budget regardless — see DESIGN.md section Hardware-Adaptation.
"""

from . import ref
from .ho_attention import ho_attention_pallas, ho_attention_causal_pallas
from .linear_attention import (linear_attention_pallas,
                               linear_attention_causal_pallas)
from .softmax_attention import softmax_attention_pallas
from .layernorm import layernorm_noaffine_pallas
