"""Chunked-scan causal linear attention in pure jnp (memory-light).

The cumsum forms in ref.py materialize the running state for every
position — O(n * f * d_v) memory, which for the order-2 feature dimension
f = 1 + d + d^2 is infeasible beyond toy sizes.  These versions scan over
sequence chunks carrying one (f, d_v) state, exactly mirroring the causal
Pallas kernel (ho_attention.py::_causal_kernel) — they are the fused-XLA
implementation the L2 training graph uses, and they are what the paper's
complexity claim actually describes: O(n d_v d^2) time, O(f d_v) space.

Within a chunk the (c x c) Taylor attention matrix is formed directly
(<phi(q), phi(k)> == taylor(q.k / a sqrt d), so this is exact and cheaper
than materializing phi when c <= f); across chunks the (S, z) carry
propagates.  Tested equal to ref.ho_attention / ref.linear_attention.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .ref import (EPS_DEN, elu_feature_map, ho_feature_dim, ho_feature_map,
                  layernorm_noaffine, taylor_exp)

DEFAULT_CHUNK = 128


def _chunked_scan(fq, fk, v, a_intra):
    """Shared scan: fq/fk (nc, c, f), v (nc, c, dv), a_intra (nc, c, c)."""
    f, dv = fq.shape[-1], v.shape[-1]

    def step(carry, inp):
        s_mat, z = carry
        fq_c, fk_c, v_c, a_c = inp
        num = fq_c @ s_mat + a_c @ v_c
        den = fq_c @ z[:, None] + jnp.sum(a_c, axis=-1, keepdims=True)
        out = num / jnp.maximum(den, EPS_DEN)
        s_mat = s_mat + fk_c.T @ v_c
        z = z + jnp.sum(fk_c, axis=0)
        return (s_mat, z), out

    init = (jnp.zeros((f, dv), fq.dtype), jnp.zeros((f,), fq.dtype))
    _, out = jax.lax.scan(step, init, (fq, fk, v, a_intra))
    return out  # (nc, c, dv)


def _tril(a):
    rows = jax.lax.broadcasted_iota(jnp.int32, a.shape, a.ndim - 2)
    cols = jax.lax.broadcasted_iota(jnp.int32, a.shape, a.ndim - 1)
    return jnp.where(rows >= cols, a, 0.0)


@functools.partial(jax.jit, static_argnames=("order", "alpha",
                                             "normalize_qk", "chunk"))
def _ho_single(q, k, v, *, order, alpha, normalize_qk, chunk):
    n, d = q.shape
    dv = v.shape[-1]
    c = min(chunk, n)
    assert n % c == 0, f"seq len {n} not divisible by chunk {c}"
    if normalize_qk:
        q, k = layernorm_noaffine(q), layernorm_noaffine(k)
    scale = 1.0 / (alpha * jnp.sqrt(jnp.asarray(d, q.dtype)))
    qc = q.reshape(n // c, c, d)
    kc = k.reshape(n // c, c, d)
    vc = v.reshape(n // c, c, dv)
    fq = ho_feature_map(qc, alpha, order)
    fk = ho_feature_map(kc, alpha, order)
    a_intra = _tril(taylor_exp(
        jnp.einsum("ncd,nmd->ncm", qc, kc) * scale, order))
    return _chunked_scan(fq, fk, vc, a_intra).reshape(n, dv)


def ho_attention_chunked(q, k, v, *, order=2, alpha=3.0, normalize_qk=True,
                         chunk=DEFAULT_CHUNK):
    """Causal HO attention via chunked scan; q/k/v: (..., n, d)."""
    fn = functools.partial(_ho_single, order=order, alpha=alpha,
                           normalize_qk=normalize_qk, chunk=chunk)
    for _ in range(q.ndim - 2):
        fn = jax.vmap(fn)
    return fn(q, k, v)


@functools.partial(jax.jit, static_argnames=("chunk",))
def _linear_single(q, k, v, *, chunk):
    n, d = q.shape
    dv = v.shape[-1]
    c = min(chunk, n)
    assert n % c == 0
    qc = q.reshape(n // c, c, d)
    kc = k.reshape(n // c, c, d)
    vc = v.reshape(n // c, c, dv)
    fq, fk = elu_feature_map(qc), elu_feature_map(kc)
    a_intra = _tril(jnp.einsum("ncf,nmf->ncm", fq, fk))
    return _chunked_scan(fq, fk, vc, a_intra).reshape(n, dv)


def linear_attention_chunked(q, k, v, *, chunk=DEFAULT_CHUNK):
    """Causal elu+1 linear attention via chunked scan; q/k/v: (..., n, d)."""
    fn = functools.partial(_linear_single, chunk=chunk)
    for _ in range(q.ndim - 2):
        fn = jax.vmap(fn)
    return fn(q, k, v)
