"""Pure-jnp reference oracles for every kernel in this package.

These are the ground truth the Pallas kernels are tested against, and they
double as the fast XLA-fused implementation used inside the L2 training
graph (`model.py` with impl="jnp") — the Pallas path (`impl="pallas"`) runs
under interpret=True on CPU, which is structurally faithful to the TPU
kernel but much slower to execute, so we reserve it for kernel tests,
the quickstart artifact and the structure-level perf analysis.

Math (paper, Mercat 2020 "Higher Order Linear Transformer"):

    softmax(Q K^T / (a sqrt(d))) V  is approximated through the 2nd-order
    Taylor expansion of exp:  exp(x) ~ 1 + x + x^2/2,  x = q~.k~/(a sqrt(d))

with q~, k~ layer-normalized (no affine).  Every term factorizes over the
sequence dimension via the feature map

    phi(u) = [ 1,  u / sqrt(s),  vec(u (x) u) / (sqrt(2) s) ],  s = a sqrt(d)

so that  <phi(q), phi(k)> = 1 + x + x^2/2  exactly.  Attention becomes

    out_i = phi(q_i) . ( sum_j phi(k_j) v_j^T )  /  phi(q_i) . sum_j phi(k_j)

which is O(n d_v d^2) instead of O(n^2 d) (paper eq. 3).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

EPS_DEN = 1e-6  # denominator clamp, as in fast-transformers


# ---------------------------------------------------------------------------
# layer norm (no affine) — paper section 3
# ---------------------------------------------------------------------------

def layernorm_noaffine(x: jax.Array, eps: float = 1e-5) -> jax.Array:
    """LayerNorm over the last axis without the element-wise affine."""
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.var(x, axis=-1, keepdims=True)
    return (x - mu) * jax.lax.rsqrt(var + eps)


def layernorm_affine(x: jax.Array, g: jax.Array, b: jax.Array,
                     eps: float = 1e-5) -> jax.Array:
    """Standard LayerNorm with scale/shift (used by transformer blocks)."""
    return layernorm_noaffine(x, eps) * g + b


# ---------------------------------------------------------------------------
# Taylor expansion of exp — paper figure 1
# ---------------------------------------------------------------------------

def taylor_exp(x: jax.Array, order: int) -> jax.Array:
    """sum_{i<=order} x^i / i!  — the paper's exp approximation."""
    acc = jnp.ones_like(x)
    term = jnp.ones_like(x)
    for i in range(1, order + 1):
        term = term * x / i
        acc = acc + term
    return acc


# ---------------------------------------------------------------------------
# feature map — the factorized form of the order-2 Taylor expansion
# ---------------------------------------------------------------------------

def ho_feature_map(u: jax.Array, alpha: float, order: int) -> jax.Array:
    """phi(u): map (..., d) -> (..., d_f) with d_f = 1 [+ d [+ d^2]].

    <phi(q), phi(k)> == taylor_exp(q.k / (alpha sqrt(d)), order).
    """
    d = u.shape[-1]
    s = alpha * jnp.sqrt(jnp.asarray(d, u.dtype))
    parts = [jnp.ones(u.shape[:-1] + (1,), u.dtype)]
    if order >= 1:
        parts.append(u * jax.lax.rsqrt(s))
    if order >= 2:
        outer = u[..., :, None] * u[..., None, :]  # (..., d, d)
        parts.append(outer.reshape(u.shape[:-1] + (d * d,)) /
                     (jnp.sqrt(jnp.asarray(2.0, u.dtype)) * s))
    if order >= 3:
        raise NotImplementedError(
            "order>=3 costs n*d_v*d^3 — the paper argues this is unlikely "
            "to be worthwhile (section 4); not implemented")
    return jnp.concatenate(parts, axis=-1)


def ho_feature_dim(d: int, order: int) -> int:
    """Feature dimension of `ho_feature_map` for head dim d."""
    return 1 + (d if order >= 1 else 0) + (d * d if order >= 2 else 0)


# ---------------------------------------------------------------------------
# attention oracles.  all take (..., n, d) q/k and (..., n, d_v) v where the
# leading axes are batch-like (batch, heads).
# ---------------------------------------------------------------------------

def ho_attention_direct(q, k, v, *, order: int = 2, alpha: float = 3.0,
                        causal: bool = False, normalize_qk: bool = True):
    """Quadratic-cost direct evaluation of the paper's approximation.

    Materializes taylor_exp(Q~ K~^T / (a sqrt d)) — used only as an oracle
    to check the factorized O(n d^2) implementations against.
    """
    if normalize_qk:
        q, k = layernorm_noaffine(q), layernorm_noaffine(k)
    d = q.shape[-1]
    x = jnp.einsum("...nd,...md->...nm", q, k) / (alpha * jnp.sqrt(
        jnp.asarray(d, q.dtype)))
    a = taylor_exp(x, order)
    if causal:
        n, m = a.shape[-2], a.shape[-1]
        mask = jnp.tril(jnp.ones((n, m), bool))
        a = jnp.where(mask, a, 0.0)
    den = jnp.maximum(jnp.sum(a, axis=-1, keepdims=True), EPS_DEN)
    return jnp.einsum("...nm,...mv->...nv", a / den, v)


def ho_attention(q, k, v, *, order: int = 2, alpha: float = 3.0,
                 causal: bool = False, normalize_qk: bool = True):
    """Factorized linear-complexity form (paper eq. 2-3) via the feature map.

    Non-causal:  out_i = phi(q_i) S / max(phi(q_i) z, eps)
                 with S = sum_j phi(k_j) v_j^T,  z = sum_j phi(k_j).
    Causal: prefix sums (the 'transformers are RNNs' view, lifted to order 2).
    """
    if normalize_qk:
        q, k = layernorm_noaffine(q), layernorm_noaffine(k)
    fq = ho_feature_map(q, alpha, order)  # (..., n, f)
    fk = ho_feature_map(k, alpha, order)
    if not causal:
        s = jnp.einsum("...nf,...nv->...fv", fk, v)
        z = jnp.sum(fk, axis=-2)
        num = jnp.einsum("...nf,...fv->...nv", fq, s)
        den = jnp.einsum("...nf,...f->...n", fq, z)
    else:
        s = jnp.cumsum(fk[..., :, :, None] * v[..., :, None, :], axis=-3)
        z = jnp.cumsum(fk, axis=-2)
        num = jnp.einsum("...nf,...nfv->...nv", fq, s)
        den = jnp.einsum("...nf,...nf->...n", fq, z)
    return num / jnp.maximum(den, EPS_DEN)[..., None]


def elu_feature_map(u: jax.Array) -> jax.Array:
    """elu(u)+1 — the Katharopoulos et al. 2020 baseline feature map."""
    return jax.nn.elu(u) + 1.0


def linear_attention(q, k, v, *, causal: bool = False):
    """First-order linear attention baseline (Katharopoulos et al. 2020)."""
    fq, fk = elu_feature_map(q), elu_feature_map(k)
    if not causal:
        s = jnp.einsum("...nf,...nv->...fv", fk, v)
        z = jnp.sum(fk, axis=-2)
        num = jnp.einsum("...nf,...fv->...nv", fq, s)
        den = jnp.einsum("...nf,...f->...n", fq, z)
    else:
        s = jnp.cumsum(fk[..., :, :, None] * v[..., :, None, :], axis=-3)
        z = jnp.cumsum(fk, axis=-2)
        num = jnp.einsum("...nf,...nfv->...nv", fq, s)
        den = jnp.einsum("...nf,...nf->...n", fq, z)
    return num / jnp.maximum(den, EPS_DEN)[..., None]


def softmax_attention(q, k, v, *, causal: bool = False,
                      scale: float | None = None):
    """Exact softmax attention baseline (Vaswani et al. 2017), O(n^2 d)."""
    d = q.shape[-1]
    if scale is None:
        scale = 1.0 / jnp.sqrt(jnp.asarray(d, q.dtype))
    x = jnp.einsum("...nd,...md->...nm", q, k) * scale
    if causal:
        n, m = x.shape[-2], x.shape[-1]
        mask = jnp.tril(jnp.ones((n, m), bool))
        x = jnp.where(mask, x, -jnp.inf)
    a = jax.nn.softmax(x, axis=-1)
    return jnp.einsum("...nm,...mv->...nv", a, v)


# ---------------------------------------------------------------------------
# recurrent (decode-time) steps — O(1) per token
# ---------------------------------------------------------------------------

def ho_decode_step(q_t, k_t, v_t, state, *, order: int = 2,
                   alpha: float = 3.0, normalize_qk: bool = True):
    """One autoregressive step of causal HO attention.

    state = (S, z): S (..., f, d_v) running sum of phi(k) v^T, z (..., f).
    Returns (out_t, new_state).  q_t/k_t: (..., d); v_t: (..., d_v).
    """
    if normalize_qk:
        q_t, k_t = layernorm_noaffine(q_t), layernorm_noaffine(k_t)
    s_mat, z = state
    fq = ho_feature_map(q_t, alpha, order)
    fk = ho_feature_map(k_t, alpha, order)
    s_mat = s_mat + fk[..., :, None] * v_t[..., None, :]
    z = z + fk
    num = jnp.einsum("...f,...fv->...v", fq, s_mat)
    den = jnp.maximum(jnp.einsum("...f,...f->...", fq, z), EPS_DEN)
    return num / den[..., None], (s_mat, z)


def linear_decode_step(q_t, k_t, v_t, state):
    """One autoregressive step of causal elu+1 linear attention."""
    s_mat, z = state
    fq, fk = elu_feature_map(q_t), elu_feature_map(k_t)
    s_mat = s_mat + fk[..., :, None] * v_t[..., None, :]
    z = z + fk
    num = jnp.einsum("...f,...fv->...v", fq, s_mat)
    den = jnp.maximum(jnp.einsum("...f,...f->...", fq, z), EPS_DEN)
    return num / den[..., None], (s_mat, z)


def softmax_decode_step(q_t, kcache, vcache, pos, *, scale=None):
    """One step of exact attention against a (max_len) KV cache.

    kcache/vcache: (..., max_len, d); entries >= pos are masked out.
    (The caches must already contain k_t/v_t at index pos-1.)
    """
    d = q_t.shape[-1]
    if scale is None:
        scale = 1.0 / jnp.sqrt(jnp.asarray(d, q_t.dtype))
    x = jnp.einsum("...d,...md->...m", q_t, kcache) * scale
    idx = jnp.arange(kcache.shape[-2])
    x = jnp.where(idx < pos, x, -jnp.inf)
    a = jax.nn.softmax(x, axis=-1)
    return jnp.einsum("...m,...mv->...v", a, vcache)
