"""Pallas kernels for the paper's higher-order linear attention.

Two kernels implement the factorized form of
``softmax(QK^T/(a sqrt d)) V ~ (1 + X + X.X/2) V`` (paper eq. 2-3):

* non-causal: a **state kernel** sweeps the sequence once accumulating
  ``S = sum_j phi(k_j) v_j^T`` and ``z = sum_j phi(k_j)`` in VMEM, then a
  **query kernel** computes ``phi(q_i) S / phi(q_i) z`` block-by-block.
* causal: a single **chunked-scan kernel** — within a chunk the (c x c)
  Taylor attention matrix is formed in VMEM and masked lower-triangular;
  across chunks the running ``(S, z)`` state is carried in VMEM scratch.
  This is the TPU translation of the paper's "transformers are RNNs"
  recurrence: the GPU fork scans per-thread, we scan per sequence chunk.

TPU mapping (DESIGN.md section Hardware-Adaptation): the d^2 feature
dimension of ``phi`` exists only in VMEM — phi(k-block) is (re)computed on
the fly from the (block_n, d) tile and fed straight to the MXU contraction,
never written to HBM.  BlockSpecs express the HBM->VMEM schedule the CUDA
version expressed with threadblocks.
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .ref import EPS_DEN, ho_feature_dim

DEFAULT_BLOCK_N = 128  # sequence tile: 128 rows feeds the 128x128 MXU


def _ln_noaffine(x, eps=1e-5):
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.var(x, axis=-1, keepdims=True)
    return (x - mu) * jax.lax.rsqrt(var + eps)


def _phi(u, alpha: float, order: int):
    """Feature map on a (bn, d) block -> (bn, f); f = 1 [+ d [+ d^2]].

    Matches ref.ho_feature_map exactly (shared constants), but written
    block-local so it lives in VMEM only.
    """
    bn, d = u.shape
    s = alpha * math.sqrt(d)
    parts = [jnp.ones((bn, 1), u.dtype)]
    if order >= 1:
        parts.append(u / math.sqrt(s))
    if order >= 2:
        outer = u[:, :, None] * u[:, None, :]
        parts.append(outer.reshape(bn, d * d) / (math.sqrt(2.0) * s))
    return jnp.concatenate(parts, axis=-1)


def _taylor(x, order: int):
    acc = jnp.ones_like(x)
    term = jnp.ones_like(x)
    for i in range(1, order + 1):
        term = term * x / i
        acc = acc + term
    return acc


# ---------------------------------------------------------------------------
# non-causal: state kernel + query kernel
# ---------------------------------------------------------------------------

def _state_kernel(k_ref, v_ref, s_ref, z_ref, *, alpha, order, normalize_qk):
    """Accumulate S += phi(k_blk)^T v_blk and z += sum phi(k_blk)."""
    @pl.when(pl.program_id(0) == 0)
    def _init():
        s_ref[...] = jnp.zeros_like(s_ref)
        z_ref[...] = jnp.zeros_like(z_ref)

    k = k_ref[...]
    if normalize_qk:
        k = _ln_noaffine(k)
    fk = _phi(k, alpha, order)                        # (bn, f) in VMEM only
    s_ref[...] += jax.lax.dot_general(
        fk, v_ref[...], (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)           # (f, dv) MXU
    z_ref[...] += jnp.sum(fk, axis=0, keepdims=True)  # (1, f)


def _query_kernel(q_ref, s_ref, z_ref, o_ref, *, alpha, order, normalize_qk):
    """o_blk = phi(q_blk) S / max(phi(q_blk) z, eps)."""
    q = q_ref[...]
    if normalize_qk:
        q = _ln_noaffine(q)
    fq = _phi(q, alpha, order)                        # (bn, f)
    num = jax.lax.dot_general(
        fq, s_ref[...], (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)           # (bn, dv)
    den = jax.lax.dot_general(
        fq, z_ref[...], (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)           # (bn, 1)
    o_ref[...] = num / jnp.maximum(den, EPS_DEN)


@functools.partial(jax.jit, static_argnames=("order", "alpha", "normalize_qk",
                                             "block_n", "interpret"))
def _ho_attention_single(q, k, v, *, order=2, alpha=3.0, normalize_qk=True,
                         block_n=DEFAULT_BLOCK_N, interpret=True):
    """Non-causal HO attention for one (n, d) problem."""
    n, d = q.shape
    dv = v.shape[-1]
    bn = min(block_n, n)
    assert n % bn == 0, f"seq len {n} not divisible by block {bn}"
    f = ho_feature_dim(d, order)

    s_mat, z = pl.pallas_call(
        functools.partial(_state_kernel, alpha=alpha, order=order,
                          normalize_qk=normalize_qk),
        grid=(n // bn,),
        in_specs=[pl.BlockSpec((bn, d), lambda i: (i, 0)),
                  pl.BlockSpec((bn, dv), lambda i: (i, 0))],
        out_specs=[pl.BlockSpec((f, dv), lambda i: (0, 0)),
                   pl.BlockSpec((1, f), lambda i: (0, 0))],
        out_shape=[jax.ShapeDtypeStruct((f, dv), jnp.float32),
                   jax.ShapeDtypeStruct((1, f), jnp.float32)],
        interpret=interpret,
    )(k, v)

    return pl.pallas_call(
        functools.partial(_query_kernel, alpha=alpha, order=order,
                          normalize_qk=normalize_qk),
        grid=(n // bn,),
        in_specs=[pl.BlockSpec((bn, d), lambda i: (i, 0)),
                  pl.BlockSpec((f, dv), lambda i: (0, 0)),
                  pl.BlockSpec((1, f), lambda i: (0, 0))],
        out_specs=pl.BlockSpec((bn, dv), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n, dv), jnp.float32),
        interpret=interpret,
    )(q, s_mat, z)


def ho_attention_pallas(q, k, v, *, order=2, alpha=3.0, normalize_qk=True,
                        block_n=DEFAULT_BLOCK_N, interpret=True):
    """Non-causal higher-order linear attention; q/k/v: (..., n, d)."""
    fn = functools.partial(_ho_attention_single, order=order, alpha=alpha,
                           normalize_qk=normalize_qk, block_n=block_n,
                           interpret=interpret)
    for _ in range(q.ndim - 2):
        fn = jax.vmap(fn)
    return fn(q, k, v)


# ---------------------------------------------------------------------------
# causal: chunked scan with VMEM-resident (S, z) carry
# ---------------------------------------------------------------------------

def _causal_kernel(q_ref, k_ref, v_ref, o_ref, s_ref, z_ref,
                   *, alpha, order, normalize_qk, block_n):
    """One chunk of the causal scan.

    out_chunk = (phi(q) S_prev + tril(taylor(q k^T / a sqrt d)) v)
              / (phi(q) z_prev + rowsum(tril(...)))
    then S_prev += phi(k)^T v ; z_prev += sum phi(k).
    """
    @pl.when(pl.program_id(0) == 0)
    def _init():
        s_ref[...] = jnp.zeros_like(s_ref)
        z_ref[...] = jnp.zeros_like(z_ref)

    q, k, v = q_ref[...], k_ref[...], v_ref[...]
    if normalize_qk:
        q, k = _ln_noaffine(q), _ln_noaffine(k)
    d = q.shape[-1]
    scale = 1.0 / (alpha * math.sqrt(d))

    # cross-chunk term (strictly earlier chunks) via the carried state
    fq = _phi(q, alpha, order)
    num = jax.lax.dot_general(fq, s_ref[...], (((1,), (0,)), ((), ())),
                              preferred_element_type=jnp.float32)
    den = jax.lax.dot_general(fq, z_ref[...], (((1,), (1,)), ((), ())),
                              preferred_element_type=jnp.float32)

    # intra-chunk term: exact (c x c) Taylor matrix, lower-triangular.
    # <phi(q_i), phi(k_j)> == taylor(x_ij) so forming it directly is the
    # cheaper equivalent when c <= f.
    x = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale
    a = _taylor(x, order)
    rows = jax.lax.broadcasted_iota(jnp.int32, a.shape, 0)
    cols = jax.lax.broadcasted_iota(jnp.int32, a.shape, 1)
    a = jnp.where(rows >= cols, a, 0.0)
    num += jax.lax.dot_general(a, v, (((1,), (0,)), ((), ())),
                               preferred_element_type=jnp.float32)
    den += jnp.sum(a, axis=-1, keepdims=True)

    o_ref[...] = num / jnp.maximum(den, EPS_DEN)

    # fold this chunk into the carry for the next grid step
    fk = _phi(k, alpha, order)
    s_ref[...] += jax.lax.dot_general(fk, v, (((0,), (0,)), ((), ())),
                                      preferred_element_type=jnp.float32)
    z_ref[...] += jnp.sum(fk, axis=0, keepdims=True)


@functools.partial(jax.jit, static_argnames=("order", "alpha", "normalize_qk",
                                             "block_n", "interpret"))
def _ho_attention_causal_single(q, k, v, *, order=2, alpha=3.0,
                                normalize_qk=True, block_n=DEFAULT_BLOCK_N,
                                interpret=True):
    n, d = q.shape
    dv = v.shape[-1]
    bn = min(block_n, n)
    assert n % bn == 0, f"seq len {n} not divisible by block {bn}"
    f = ho_feature_dim(d, order)

    return pl.pallas_call(
        functools.partial(_causal_kernel, alpha=alpha, order=order,
                          normalize_qk=normalize_qk, block_n=bn),
        grid=(n // bn,),
        in_specs=[pl.BlockSpec((bn, d), lambda i: (i, 0)),
                  pl.BlockSpec((bn, d), lambda i: (i, 0)),
                  pl.BlockSpec((bn, dv), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((bn, dv), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n, dv), jnp.float32),
        scratch_shapes=[pltpu.VMEM((f, dv), jnp.float32),
                        pltpu.VMEM((1, f), jnp.float32)],
        interpret=interpret,
    )(q, k, v)


def ho_attention_causal_pallas(q, k, v, *, order=2, alpha=3.0,
                               normalize_qk=True, block_n=DEFAULT_BLOCK_N,
                               interpret=True):
    """Causal higher-order linear attention; q/k/v: (..., n, d)."""
    fn = functools.partial(_ho_attention_causal_single, order=order,
                           alpha=alpha, normalize_qk=normalize_qk,
                           block_n=block_n, interpret=interpret)
    for _ in range(q.ndim - 2):
        fn = jax.vmap(fn)
    return fn(q, k, v)
