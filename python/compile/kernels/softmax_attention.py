"""Blocked exact softmax attention (flash-attention style) — the baseline.

Online-softmax over (block_q x block_k) tiles with running max / running
denominator carried in VMEM scratch.  Grid is (num_q_blocks, num_k_blocks)
with the k axis innermost, so for a fixed q block the scratch accumulators
survive the whole k sweep; the final normalization is written on the last
k step.  The causal variant masks the diagonal tile and relies on
block-level skipping (the mask zeroes fully-masked tiles).
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_BLOCK_N = 128
NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref,
                  *, scale, causal, block_n, num_k):
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q, k, v = q_ref[...], k_ref[...], v_ref[...]
    x = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale
    if causal:
        i = pl.program_id(0)
        rows = i * block_n + jax.lax.broadcasted_iota(jnp.int32, x.shape, 0)
        cols = j * block_n + jax.lax.broadcasted_iota(jnp.int32, x.shape, 1)
        x = jnp.where(rows >= cols, x, NEG_INF)

    m_prev, l_prev = m_ref[...], l_ref[...]
    m_cur = jnp.max(x, axis=-1, keepdims=True)
    m_new = jnp.maximum(m_prev, m_cur)
    p = jnp.exp(x - m_new)
    corr = jnp.exp(m_prev - m_new)
    l_new = corr * l_prev + jnp.sum(p, axis=-1, keepdims=True)
    acc_ref[...] = acc_ref[...] * corr + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    m_ref[...], l_ref[...] = m_new, l_new

    @pl.when(j == num_k - 1)
    def _finalize():
        o_ref[...] = acc_ref[...] / jnp.maximum(l_ref[...], 1e-30)


@functools.partial(jax.jit, static_argnames=("causal", "block_n",
                                             "interpret"))
def _softmax_attention_single(q, k, v, *, causal=False,
                              block_n=DEFAULT_BLOCK_N, interpret=True):
    n, d = q.shape
    dv = v.shape[-1]
    bn = min(block_n, n)
    assert n % bn == 0
    num_k = n // bn
    scale = 1.0 / math.sqrt(d)

    return pl.pallas_call(
        functools.partial(_flash_kernel, scale=scale, causal=causal,
                          block_n=bn, num_k=num_k),
        grid=(n // bn, num_k),
        in_specs=[pl.BlockSpec((bn, d), lambda i, j: (i, 0)),
                  pl.BlockSpec((bn, d), lambda i, j: (j, 0)),
                  pl.BlockSpec((bn, dv), lambda i, j: (j, 0))],
        out_specs=pl.BlockSpec((bn, dv), lambda i, j: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n, dv), jnp.float32),
        scratch_shapes=[pltpu.VMEM((bn, 1), jnp.float32),
                        pltpu.VMEM((bn, 1), jnp.float32),
                        pltpu.VMEM((bn, dv), jnp.float32)],
        interpret=interpret,
    )(q, k, v)


def softmax_attention_pallas(q, k, v, *, causal=False,
                             block_n=DEFAULT_BLOCK_N, interpret=True):
    """Exact blocked softmax attention; q/k/v: (..., n, d)."""
    fn = functools.partial(_softmax_attention_single, causal=causal,
                           block_n=block_n, interpret=interpret)
    for _ in range(q.ndim - 2):
        fn = jax.vmap(fn)
    return fn(q, k, v)
