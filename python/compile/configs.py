"""Model configuration presets for the HOLT reproduction.

The preset names are shared between python (AOT lowering) and rust (the
coordinator reads them back from artifacts/manifest.json), so change them
in lockstep with rust/src/config/.
"""

from __future__ import annotations

import dataclasses

# byte-level vocab: 256 raw bytes + specials, padded to a multiple of 16
# for MXU-friendly embedding/LM-head shapes.
PAD, BOS, EOS = 256, 257, 258
VOCAB_SIZE = 272


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    """Hyper-parameters of the decoder-only LM (L2 graph)."""
    name: str = "small"
    vocab_size: int = VOCAB_SIZE
    d_model: int = 256
    n_heads: int = 4
    n_layers: int = 4
    d_ff: int = 1024
    max_len: int = 256
    attn: str = "ho2"        # softmax | linear | ho2
    order: int = 2           # taylor order (ho2 only): 0, 1 or 2
    alpha: float = 3.0       # the paper's extra temperature (section 3)
    impl: str = "jnp"        # jnp (XLA-fused oracle) | pallas (L1 kernels)
    # training-artifact shapes (fixed at lowering)
    train_batch: int = 16
    train_len: int = 128
    decode_batch: int = 8

    @property
    def d_head(self) -> int:
        return self.d_model // self.n_heads

    def with_(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)

    def n_params(self) -> int:
        """Parameter count (tied embeddings)."""
        d, v, l, f = self.d_model, self.vocab_size, self.n_layers, self.d_ff
        # wq/wk/wv/wo + (w1,b1,w2,b2) + ln1(g,b) + ln2(g,b)
        per_block = 4 * d * d + 2 * d * f + f + d + 4 * d
        return v * d + self.max_len * d + l * per_block + 2 * d


PRESETS = {
    "tiny": ModelConfig(name="tiny", d_model=64, n_heads=2, n_layers=2,
                        d_ff=256, max_len=128, train_batch=8, train_len=64,
                        decode_batch=4),
    "small": ModelConfig(name="small", d_model=256, n_heads=8, n_layers=4,
                         d_ff=1024, max_len=256, train_batch=16,
                         train_len=128, decode_batch=8),
    "base": ModelConfig(name="base", d_model=512, n_heads=16, n_layers=8,
                        d_ff=2048, max_len=512, train_batch=8, train_len=256,
                        decode_batch=8),
    # ~124M parameters, GPT-2-small shaped (documented capability; lowering
    # it is supported but not part of the default `make artifacts`).
    "large": ModelConfig(name="large", vocab_size=32768, d_model=768,
                         n_heads=12, n_layers=12, d_ff=3072, max_len=1024,
                         train_batch=4, train_len=512, decode_batch=4),
}
